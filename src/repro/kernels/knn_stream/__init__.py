"""Fused streaming distance + top-K engine kernel (DESIGN.md §2.6)."""
from repro.kernels.knn_stream.ops import knn_stream_topk  # noqa: F401
