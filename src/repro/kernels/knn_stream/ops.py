"""Public wrapper for the fused streaming distance+top-K engine:
padding + dispatch (same mode policy as the other kernel packages).

Unlike ``knn_topk.ops`` there is no post-kernel merge pass: the kernel
carries the running top-K across candidate sub-blocks in VMEM scratch,
so the kernel outputs ARE the final (Q, k) results.
"""
from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp

from repro.utils import round_up
from repro.kernels.knn_stream import kernel as _kernel
from repro.kernels.knn_stream import ref as _ref

_log = logging.getLogger(__name__)

# Process-wide once-flag for the oversized-k fallback notice.  The ref
# oracle is a silent asymptotic cliff (materialize-then-sort instead of
# the streaming kernel), so the reroute is worth one loud line — but
# only one: the fallback fires per jit trace and a k sweep would
# otherwise spam a line per shape.
_oversized_k_warned = False


def _warn_oversized_k(k: int) -> None:
    global _oversized_k_warned
    if not _oversized_k_warned:
        _oversized_k_warned = True
        _log.warning(
            "knn_stream: k=%d exceeds MAX_UNROLLED_K=%d — routing to the "
            "materialize-then-sort ref oracle (exact, but the streaming "
            "kernel's memory ceiling no longer applies; further oversized-k "
            "traces fall back silently)",
            k, _kernel.MAX_UNROLLED_K,
        )


def _use_pallas(mode: str) -> bool:
    if mode == "auto":
        return jax.default_backend() == "tpu"
    return mode in ("pallas", "interpret")


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_c", "mode", "metric")
)
def knn_stream_topk(
    queries: jnp.ndarray,      # (Q, D)
    candidates: jnp.ndarray,   # (C, D)
    query_ids: jnp.ndarray,    # (Q,) i32
    cand_ids: jnp.ndarray,     # (C,) i32, −1 = invalid row
    eps2: jnp.ndarray,         # () f32 — traced ε² (runtime operand)
    *,
    k: int,
    block_q: int = 128,
    block_c: int = 128,
    mode: str = "auto",
    metric: str = "l2",
):
    """One-pass ε-filtered top-K over arbitrary (unpadded) shapes.

    Returns (dists (Q, k) ascending inf-padded, ids (Q, k) −1-padded,
    found (Q,) i32 — in-range candidates, self/invalid excluded).

    Oversized K falls back to the ref oracle, mirroring
    ``knn_topk.ops`` (the unrolled merge network stops paying for
    itself past ``MAX_UNROLLED_K``); the first such reroute per process
    logs a warning so the cliff is visible."""
    if not _use_pallas(mode) or k > _kernel.MAX_UNROLLED_K:
        if _use_pallas(mode):
            _warn_oversized_k(k)
        return _ref.knn_stream_topk_ref(
            queries, candidates, query_ids, cand_ids, eps2, k=k, metric=metric
        )

    q_n, dim = queries.shape
    c_n, _ = candidates.shape
    qp = round_up(max(q_n, 1), block_q)
    cp = round_up(max(c_n, 1), block_c)
    q = jnp.zeros((qp, dim), queries.dtype).at[:q_n].set(queries)
    c = jnp.zeros((cp, dim), candidates.dtype).at[:c_n].set(candidates)
    qid = jnp.full((qp,), -1, jnp.int32).at[:q_n].set(query_ids.astype(jnp.int32))
    cid = jnp.full((cp,), -1, jnp.int32).at[:c_n].set(cand_ids.astype(jnp.int32))

    kd, ki, found = _kernel.knn_stream_topk_padded(
        q, c, qid, cid, eps2, k=k, block_q=block_q, block_c=block_c,
        metric=metric, interpret=(mode == "interpret"),
    )
    return kd[:q_n], ki[:q_n], found[:q_n]


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_c", "mode", "metric")
)
def knn_stream_topk_prefetch(
    queries: jnp.ndarray,      # (T·block_q, D)
    corpus: jnp.ndarray,       # (C, D), C % block_c == 0
    block_table: jnp.ndarray,  # (T, nblk) i32 — scalar-prefetch DMA schedule
    query_ids: jnp.ndarray,    # (T·block_q,) i32 exclusion ids
    cand_ids: jnp.ndarray,     # (T, nblk·block_c) i32, −1 = masked row
    eps2: jnp.ndarray,         # () f32
    *,
    k: int,
    block_q: int = 128,
    block_c: int = 128,
    mode: str = "auto",
    metric: str = "l2",
):
    """Dispatch for the scalar-prefetch streaming kernel (operands are
    pre-padded by the dense engine — the block table fixes the shapes).

    ``"ref"`` mode materializes the same block-aligned candidate operand
    by an explicit gather (the oracle); oversized k raises — callers
    route oversized k through the gathered path instead, where the
    budget-shaped operand the oracle needs already exists."""
    if not _use_pallas(mode):
        return _ref.knn_stream_topk_prefetch_ref(
            queries, corpus, block_table, query_ids, cand_ids, eps2,
            k=k, block_q=block_q, block_c=block_c, metric=metric,
        )
    return _kernel.knn_stream_topk_prefetch(
        queries, corpus, block_table, query_ids, cand_ids, eps2,
        k=k, block_q=block_q, block_c=block_c, metric=metric,
        interpret=(mode == "interpret"),
    )
