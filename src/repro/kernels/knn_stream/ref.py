"""Pure-jnp oracle for the fused streaming distance+top-K engine.

Deliberately materialize-then-sort: the full (Q, C) distance matrix via
the broadcast-subtract formulation (same rounding as the dense engine's
``"ref"`` backend), ε-masked, then one native ``top_k``.  The streaming
kernel must agree with this modulo last-ulp ε²-boundary rounding between
the two distance formulations (DESIGN.md §2.5 boundary caveat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def knn_stream_topk_ref(
    queries: jnp.ndarray,     # (Q, D)
    candidates: jnp.ndarray,  # (C, D)
    query_ids: jnp.ndarray,   # (Q,) i32
    cand_ids: jnp.ndarray,    # (C,) i32, −1 = invalid
    eps2: jnp.ndarray,        # () f32
    *,
    k: int,
    metric: str = "l2",
):
    """ε-filtered exact K nearest candidates per query.

    Returns (dists (Q, k) f32 ascending inf-padded, ids (Q, k) i32
    −1-padded, found (Q,) i32).  ``metric="ip"`` scores are −q·c (pass
    eps2=+inf to disable the score-threshold filter)."""
    q = queries.astype(jnp.float32)
    c = candidates.astype(jnp.float32)
    if metric == "ip":
        d = -(q @ c.T)                                         # (Q, C)
    else:
        diff = q[:, None, :] - c[None, :, :]
        d = jnp.sum(diff * diff, axis=-1)                      # (Q, C)
    keep = (
        (cand_ids[None, :] >= 0)
        & (query_ids[:, None] != cand_ids[None, :])
        & (d <= eps2)
    )
    dm = jnp.where(keep, d, jnp.inf)
    neg, sel = jax.lax.top_k(-dm, k)
    kd = -neg
    ki = jnp.where(jnp.isinf(kd), -1, cand_ids[sel])
    found = jnp.sum(keep, axis=1).astype(jnp.int32)
    return kd, ki, found


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_c", "metric"))
def knn_stream_topk_prefetch_ref(
    queries: jnp.ndarray,      # (T·block_q, D)
    corpus: jnp.ndarray,       # (C, D), C % block_c == 0
    block_table: jnp.ndarray,  # (T, nblk) i32
    query_ids: jnp.ndarray,    # (T·block_q,) i32 exclusion ids
    cand_ids: jnp.ndarray,     # (T, nblk·block_c) i32, −1 = masked row
    eps2: jnp.ndarray,         # () f32
    *,
    k: int,
    block_q: int = 128,
    block_c: int = 128,
    metric: str = "l2",
):
    """Oracle for the scalar-prefetch kernel: materialize each tile's
    block-aligned candidate operand by an explicit gather — the exact
    data movement the prefetch kernel's index maps perform via DMA — and
    run the materialize-then-sort oracle per tile."""
    n_tiles, nblk = block_table.shape
    dim = queries.shape[1]
    q_t = queries.astype(jnp.float32).reshape(n_tiles, block_q, dim)
    qid_t = query_ids.reshape(n_tiles, block_q)

    def one(args):
        q, qid, blk, cid = args
        rows = blk[:, None] * block_c + jnp.arange(block_c, dtype=jnp.int32)
        cand = corpus[rows.reshape(-1)].astype(jnp.float32)    # (nblk·bc, D)
        return knn_stream_topk_ref(q, cand, qid, cid, eps2, k=k, metric=metric)

    kd, ki, found = jax.lax.map(one, (q_t, qid_t, block_table, cand_ids))
    return (kd.reshape(-1, k), ki.reshape(-1, k), found.reshape(-1))
