"""Deterministic, sharded, checkpointable synthetic data pipelines.

Training at scale needs a pipeline whose state is (a) tiny (one integer),
(b) exactly resumable after restart, and (c) identical regardless of how
many hosts feed it.  We meet all three with counter-keyed PRNG synthesis:
batch ``i`` is a pure function of ``(seed, i)`` — the checkpoint stores
only the step cursor, and elastic restarts on a different mesh re-slice
the same global batch.

``TokenPipeline`` produces LM token batches (plus stub modality inputs
for the audio/VLM archs); ``batch_for(cfg, shape)`` builds the matching
batch for any (arch × shape) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineState:
    """The entire checkpointable state: a cursor."""
    step: int = 0


class TokenPipeline:
    """Counter-keyed synthetic LM batches with a Zipf-ish unigram mix —
    enough signal for loss-goes-down integration tests while staying
    fully deterministic and restart-exact."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg = cfg
        self.batch = batch_override or shape.global_batch
        self.seq = seq_override or shape.seq_len
        self.seed = seed
        self.state = PipelineState()

    # -- synthesis -------------------------------------------------------

    def _synth(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        v = cfg.vocab_size
        s_text = self.seq
        if cfg.n_patches:
            s_text = max(self.seq - cfg.n_patches, 8)
        # Zipf-ish unigram distribution + short-range repetition structure
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(self.batch, s_text + 1), p=probs)
        rep = rng.random((self.batch, s_text + 1)) < 0.3
        rep[:, 0] = False
        idx = np.where(rep)
        toks[idx] = toks[idx[0], idx[1] - 1]       # 30% copy-previous
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.n_encoder_layers:
            batch["frames"] = rng.standard_normal(
                (self.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.n_patches:
            batch["patches"] = rng.standard_normal(
                (self.batch, cfg.n_patches, cfg.patch_dim)).astype(np.float32)
        return batch

    # -- iteration -------------------------------------------------------

    def next_batch(self, sharding=None) -> Dict[str, Any]:
        """Next global batch; optionally placed with a NamedSharding."""
        host = self._synth(self.state.step)
        self.state.step += 1
        if sharding is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        out = {}
        for k, v in host.items():
            shd = sharding if not isinstance(sharding, dict) else sharding[k]
            out[k] = jax.device_put(jnp.asarray(v), shd)
        return out

    def peek(self, step: int) -> Dict[str, np.ndarray]:
        """Batch ``step`` without advancing (determinism tests)."""
        return self._synth(step)

    # -- checkpointing ----------------------------------------------------

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.state.step, "seed": self.seed}

    def load_state_dict(self, d: Dict[str, int]):
        assert d["seed"] == self.seed, "pipeline seed mismatch on restore"
        self.state.step = int(d["step"])
