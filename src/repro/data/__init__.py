"""Data substrate: deterministic sharded LM pipeline + paper point clouds."""
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.data import pointclouds

__all__ = ["PipelineState", "TokenPipeline", "pointclouds"]
