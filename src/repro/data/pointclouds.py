"""Synthetic point-cloud generators mirroring the paper's four datasets.

The paper evaluates on SuSy (5M×18d), CHist (68k×32d), Songs (515k×90d),
FMA (107k×518d) from the UCI repository.  Offline we synthesize clouds
with the same *workload-shaping* properties the paper identifies —
dimensionality, size, and density skew (dense clusters + sparse
background, which is exactly what the β/γ/ρ split keys on).  Scale
factors shrink |D| so CPU benches finish; the relative comparisons
(hybrid vs refimpl vs brute) are preserved.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class CloudSpec:
    name: str
    n_points: int
    n_dims: int
    n_clusters: int          # dense Gaussian clusters
    cluster_frac: float      # fraction of points inside clusters
    cluster_sigma: float
    intrinsic_dims: int      # dims carrying variance (rest near-constant —
                             # what REORDER exploits)


# Scaled-down analogues (same n, same density character, smaller |D|).
SPECS: Dict[str, CloudSpec] = {
    "susy": CloudSpec("susy", 20000, 18, 24, 0.75, 0.03, 18),
    "chist": CloudSpec("chist", 8000, 32, 12, 0.65, 0.04, 16),
    "songs": CloudSpec("songs", 12000, 90, 16, 0.55, 0.05, 30),
    "fma": CloudSpec("fma", 6000, 518, 8, 0.60, 0.05, 64),
}


def make_cloud(spec: CloudSpec, *, seed: int = 0,
               n_override: int | None = None) -> np.ndarray:
    """Dense clusters + uniform sparse background, low-variance tail dims."""
    rng = np.random.default_rng(seed)
    n = n_override or spec.n_points
    d = spec.n_dims
    n_cl = int(n * spec.cluster_frac)
    n_bg = n - n_cl

    centers = rng.uniform(0.15, 0.85, (spec.n_clusters, d))
    # Exponential cluster sizes — a few very dense cores (GPU-side work in
    # the paper), many small ones.
    sizes = rng.exponential(1.0, spec.n_clusters)
    sizes = np.maximum((sizes / sizes.sum() * n_cl).astype(int), 1)
    sizes[-1] += n_cl - sizes.sum()
    parts = [rng.normal(centers[i], spec.cluster_sigma, (s, d))
             for i, s in enumerate(sizes) if s > 0]
    background = rng.uniform(0.0, 1.0, (n_bg, d))
    pts = np.concatenate(parts + [background], axis=0)

    # Kill variance outside the intrinsic dims (REORDER's target property).
    if spec.intrinsic_dims < d:
        scale = np.ones(d)
        tail = rng.permutation(d)[spec.intrinsic_dims:]
        scale[tail] = 0.02
        pts = pts * scale
    rng.shuffle(pts)
    return pts.astype(np.float32)


def load(name: str, *, seed: int = 0, n_override: int | None = None) -> np.ndarray:
    return make_cloud(SPECS[name], seed=seed, n_override=n_override)
