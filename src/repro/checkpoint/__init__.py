"""Checkpoint substrate: async atomic saves, elastic restore."""
from repro.checkpoint.manager import CheckpointManager, FORMAT_VERSION

__all__ = ["CheckpointManager", "FORMAT_VERSION"]
