"""Async, atomic, elastic checkpointing.

Layout per step::

    <dir>/step-000042/
        arrays.npz          flattened "/"-joined key paths -> np arrays
        manifest.json       step, mesh shape, pipeline cursor, array index
                            (shape/dtype/bytes + crc), framework version
    <dir>/LATEST            text file naming the newest durable step

Properties required at 1000-node scale, and how each is met here:

  * durability   — writes go to ``step-N.tmp`` then atomically rename;
                   a crash mid-write can never corrupt the latest durable
                   checkpoint, and LATEST is updated only after rename.
  * async        — ``save()`` snapshots to host RAM synchronously (cheap)
                   and does serialization/IO on a background thread so the
                   train loop continues into the next step.
  * elasticity   — arrays are stored *unsharded* (gathered per host);
                   ``restore(..., shardings=...)`` re-lays them onto ANY
                   mesh, so a job restarted on fewer/more pods re-shards
                   transparently.  (On multi-host deployments the same
                   format shards per-process with a process index in the
                   manifest; this repo's single-process runtime gathers.)
  * validation   — restore checks shapes/dtypes/crc against the manifest
                   and refuses partial checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16/float8 with numpy
import numpy as np

_SEP = "/"
FORMAT_VERSION = 1

# dtypes np.savez can serialize natively; everything else (bfloat16,
# float8s) is stored as a raw byte view and reconstructed from the
# manifest's true dtype on restore.
_NATIVE_KINDS = set("biufc?")


def _encode(v: np.ndarray) -> np.ndarray:
    if v.dtype.kind in _NATIVE_KINDS:
        return v
    return np.ascontiguousarray(v).view(np.uint8)


def _decode(raw: np.ndarray, dtype: str, shape) -> np.ndarray:
    want = np.dtype(dtype)
    if raw.dtype.kind in _NATIVE_KINDS and raw.dtype == want:
        return raw
    return np.frombuffer(raw.tobytes(), dtype=want).reshape(shape)


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(jax.device_get(tree))
    return out


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray],
                    prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}{_SEP}")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten_into(v, flat, f"{prefix}{i}{_SEP}")
               for i, v in enumerate(template)]
        return type(template)(seq)
    if template is None:
        return None
    return flat[prefix.rstrip(_SEP)]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # -- save --------------------------------------------------------------

    def save(self, step: int, tree: Any, *,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot now, write in background (if async)."""
        flat = _flatten(tree)           # device->host happens here, sync
        if self._pool is None:
            self._write(step, flat, extra or {})
            return
        self.wait()                      # one in-flight write at a time
        self._pending = self._pool.submit(self._write, step, flat, extra or {})

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Dict[str, Any]) -> None:
        final = os.path.join(self.directory, f"step-{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: _encode(v) for k, v in flat.items()})
        index = {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            } for k, v in flat.items()
        }
        manifest = {
            "version": FORMAT_VERSION,
            "step": step,
            "index": index,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with self._lock:
            with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
                f.write(os.path.basename(final))
            os.replace(os.path.join(self.directory, "LATEST.tmp"),
                       os.path.join(self.directory, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step-") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))

    # -- restore -------------------------------------------------------------

    def _is_durable(self, name: str) -> bool:
        """A step directory is durable iff the atomic rename completed:
        both payload files exist under the final (non-.tmp) name."""
        d = os.path.join(self.directory, name)
        return (os.path.isdir(d)
                and os.path.exists(os.path.join(d, "manifest.json"))
                and os.path.exists(os.path.join(d, "arrays.npz")))

    def durable_steps(self) -> list:
        """All durable step numbers, ascending."""
        out = []
        for d in sorted(os.listdir(self.directory)):
            if d.startswith("step-") and not d.endswith(".tmp") \
                    and self._is_durable(d):
                try:
                    out.append(int(d.split("-")[1]))
                except ValueError:
                    continue
        return out

    def latest_step(self) -> Optional[int]:
        """Newest durable step.  The LATEST pointer is a hint, not an
        authority: a crash between the step rename and the pointer
        update (or a hand-edited/corrupt pointer) can leave it naming a
        missing or partial directory — in that case fall back to the
        newest step that actually has both payload files on disk."""
        path = os.path.join(self.directory, "LATEST")
        name = None
        if os.path.exists(path):
            with open(path) as f:
                name = f.read().strip()
        if name is not None and self._is_durable(name):
            try:
                return int(name.split("-")[1])
            except (IndexError, ValueError):
                pass  # malformed pointer content — fall through to scan
        durable = self.durable_steps()
        if durable:
            if name is not None:
                warnings.warn(
                    f"LATEST points at {name!r} which is missing or "
                    f"partial in {self.directory}; falling back to newest "
                    f"durable step {durable[-1]}", RuntimeWarning,
                    stacklevel=2)
            return durable[-1]
        return None

    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None):
        """Load into ``template``'s structure.  ``shardings`` (matching
        pytree or a single sharding) re-lays arrays onto the current mesh
        — this is the elastic-restart path."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no durable checkpoint in {self.directory} "
                    f"(nothing was ever saved, or every save crashed "
                    f"before the atomic rename)")
        name = f"step-{step:09d}"
        if not self._is_durable(name):
            durable = self.durable_steps()
            hint = (f"; durable steps available: {durable}" if durable
                    else "; no durable steps exist in this directory")
            raise FileNotFoundError(
                f"checkpoint step {step} in {self.directory} is missing "
                f"or partial (a crash mid-write leaves no durable "
                f"step-{step:09d} directory){hint}. Pass step=None to "
                f"restore the newest durable step.")
        d = os.path.join(self.directory, name)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        raw = dict(np.load(os.path.join(d, "arrays.npz")))
        flat = {}
        for k, meta in manifest["index"].items():
            v = _decode(raw[k], meta["dtype"], meta["shape"])
            if list(v.shape) != meta["shape"] or str(v.dtype) != meta["dtype"]:
                raise ValueError(f"checkpoint corrupt: {k} mismatches manifest")
            if zlib.crc32(np.ascontiguousarray(v).tobytes()) != meta["crc"]:
                raise ValueError(f"checkpoint corrupt: {k} crc mismatch")
            flat[k] = v
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            def put(x, s):
                return jax.device_put(x, s) if x is not None else None
            if jax.tree_util.tree_structure(shardings,
                                            is_leaf=lambda x: x is None) \
                    == jax.tree_util.tree_structure(tree,
                                                    is_leaf=lambda x: x is None):
                tree = jax.tree.map(put, tree, shardings)
            else:
                tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        return tree, manifest["extra"], step
